"""Serve a long-context request mix under different eviction policies and
compare quality/memory/latency — the paper's serving story in one script.

Uses the request-level API: each client request has its own prompt length,
token budget and sampling params; the engine admits them into batch slots
continuously (Engine.submit / Engine.run) instead of lockstep batches.

  PYTHONPATH=src python examples/serve_longcontext.py [--ctx 600] [--budget 96]
"""
import argparse
import time

import numpy as np

from benchmarks.common import bench_model, corpus, with_policy
from repro.core.policy import get_policy, policy_names
from repro.serving.engine import Engine, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ctx", type=int, default=600)
    ap.add_argument("--budget", type=int, default=96)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg, params = bench_model()   # trains once, then cached
    co = corpus()
    toks = np.stack([co.stream(args.ctx, seed=100 + i)
                     for i in range(args.batch)])

    # 1) policy quality/memory sweep (streaming teacher-forced scoring)
    print(f"{'policy':12s}{'budget':>8s}{'ppl':>9s}{'cacheMB':>9s}{'s/100tok':>10s}")
    for policy in policy_names():
        budget = args.budget if get_policy(policy).evicts else args.ctx
        c = with_policy(cfg, policy, budget)
        eng = Engine(c, params, budget=budget)
        t0 = time.perf_counter()
        if get_policy(policy).needs_scores:
            # score-based policies need per-step attention probabilities
            # (observe); only the token-by-token decode path produces them
            nll = eng.score_stream(toks)
        else:
            nll = eng.score_stream_chunked(toks)
        dt = (time.perf_counter() - t0) / (args.ctx * args.batch) * 100
        ppl = float(np.exp(nll.mean()))
        mb = eng.cache_bytes(eng.new_state(args.batch)) / 1e6
        print(f"{policy:12s}{budget:>8d}{ppl:>9.3f}{mb:>9.2f}{dt:>10.3f}")

    # 2) mixed-length request serving under LaCache (continuous batching,
    #    bucketed prefill: ragged lengths share power-of-two executables)
    c = with_policy(cfg, "lacache", args.budget)
    eng = Engine(c, params, budget=args.budget,
                 max_batch=max(2, args.batch // 2), bucket_prefill=True)
    for i in range(args.batch):
        plen = args.ctx // (1 + i % 3)            # deliberately ragged
        eng.submit(co.stream(plen, seed=200 + i), args.max_new,
                   SamplingParams(temperature=0.0, seed=i))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.output_tokens) for r in done)
    print(f"\nrequest mode: {len(done)} requests "
          f"({eng.scheduler.n_slots} slots) -> {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile; "
          f"{len(eng.prefill_shapes)} prefill shapes for "
          f"{args.batch} prompt lengths)")

    # 3) shared system prompt + priority admission + streamed tokens:
    #    every request extends one long prefix; only the first pays full
    #    prefill, later ones prefill their tail. A late high-priority
    #    request jumps the pending queue.
    eng = Engine(c, params, budget=args.budget, max_batch=2,
                 admission="priority")
    shared = co.stream(args.ctx, seed=300)
    first_tokens = []
    for i in range(args.batch):
        prompt = np.concatenate([shared, co.stream(8 + 4 * i, seed=301 + i)])
        eng.submit(prompt, args.max_new, SamplingParams(seed=i),
                   priority=(5 if i == args.batch - 1 else 0),
                   cache_prefix=True,
                   on_token=(lambda r, t: first_tokens.append(t))
                   if i == args.batch - 1 else None)
    done = eng.run()
    print(f"\nshared-prefix mode: prefix hit rate "
          f"{eng.prefix_hit_rate:.2f}, {eng.prefix_tokens_reused} prompt "
          f"tokens never recomputed ({eng.prefill_tokens} prefilled cold)")
    print(f"high-priority request (submitted last) streamed "
          f"{len(first_tokens)} tokens via on_token")

    # 4) paged KV backend: the same shared-prefix mix, but prefix snapshots
    #    live as block tables in one physical pool — sibling snapshots
    #    share their common blocks (copy-on-write) instead of holding
    #    dense copies, and a late urgent request can *preempt* a running
    #    one (its KV parks in the pool and resumes bit-exactly).
    eng = Engine(c, params, budget=args.budget, max_batch=2,
                 admission="deadline", kv_backend="paged")
    for i in range(args.batch):
        prompt = np.concatenate([shared, co.stream(8 + 4 * i, seed=301 + i)])
        eng.submit(prompt, args.max_new, SamplingParams(seed=i),
                   deadline=float(args.batch - i), cache_prefix=True)
    done = eng.run()
    print(f"\npaged mode: {eng.kv_bytes_in_use/1e6:.2f} MB KV pool live, "
          f"{eng.bytes_shared/1e6:.2f} MB deduplicated by block sharing "
          f"(prefix cache charges {eng.prefix_cache.nbytes/1e6:.2f} MB of "
          f"uniquely-owned bytes); {eng.preemptions} preemptions")
    print("LaCache: near-full-cache quality at streaming-cache memory.")


if __name__ == "__main__":
    main()
