"""End-to-end training driver: train a ~100M-parameter llama-mini for a few
hundred steps on the synthetic long-range corpus (deliverable b).

By default runs a CPU-sized variant; pass --full-100m for the real thing
(slow on 1 CPU core — each step is a full fwd+bwd of a 100M model).

  PYTHONPATH=src python examples/train_lm.py [--full-100m] [--steps 300]
"""
import argparse

import jax
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs.base import LaCacheConfig, ModelConfig
from repro.data.pipeline import CorpusConfig, SyntheticCorpus, lm_batches
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--out", default="results/train_lm.npz")
    args = ap.parse_args()

    if args.full_100m:
        cfg = ModelConfig(  # ~100M params
            name="llama-100m", arch_type="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=8192, dtype="float32", lacache=LaCacheConfig())
        batch, seq = 8, 512
    else:
        cfg = ModelConfig(  # ~8M params: same family, CPU-friendly
            name="llama-8m", arch_type="dense", n_layers=6, d_model=256,
            n_heads=8, n_kv_heads=4, head_dim=32, d_ff=768,
            vocab_size=2048, dtype="float32", lacache=LaCacheConfig())
        batch, seq = 8, 256

    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps "
          f"@ batch={batch} seq={seq}")
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    params, hist = trainer.train(
        cfg, params, lm_batches(corpus, batch, seq, args.steps),
        AdamWConfig(lr=1.5e-3, warmup_steps=args.steps // 10,
                    total_steps=args.steps), log_every=25)
    ckpt.save(args.out, params)
    print(f"loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}; "
          f"checkpoint: {args.out}")


if __name__ == "__main__":
    main()
