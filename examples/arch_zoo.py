"""Run every assigned architecture (reduced variant) through the public API:
one forward, one train step, one LaCache decode step — the whole zoo on CPU.

  PYTHONPATH=src python examples/arch_zoo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M
from repro.optim import adamw
from repro.train import trainer


def main():
    rng = np.random.default_rng(0)
    print(f"{'arch':24s}{'family':8s}{'params':>9s}{'fwd/s':>8s}"
          f"{'loss':>8s}{'decode':>8s}")
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch).reduced()
        params, _ = M.init(cfg, jax.random.PRNGKey(0))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params)) / 1e6
        b, t = 2, 32
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)
        ex = {}
        if cfg.n_patches:
            ex["patches"] = jnp.asarray(
                rng.normal(size=(b, cfg.n_patches, M.PATCH_DIM)), jnp.float32)
        if cfg.encoder_layers:
            ex["frames"] = jnp.asarray(
                rng.normal(size=(b, cfg.n_audio_frames, M.FRAME_DIM)),
                jnp.float32)
        t0 = time.perf_counter()
        logits, _, _ = M.forward_train(params, cfg, toks, remat=False, **ex)
        jax.block_until_ready(logits)
        fwd = time.perf_counter() - t0

        step = jax.jit(trainer.make_train_step(cfg, adamw.AdamWConfig()))
        batch = dict(tokens=jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, t + 1)), jnp.int32), **ex)
        _, _, metrics = step(params, adamw.init(params), batch)

        _, state = M.prefill(params, cfg, toks, n_slots=cfg.lacache.budget, **ex)
        lg, state = M.decode_step(params, cfg, state, toks[:, :1])
        ok = "ok" if bool(jnp.isfinite(lg).all()) else "NaN!"
        print(f"{arch:24s}{cfg.arch_type:8s}{n:8.1f}M{fwd:8.2f}"
              f"{float(metrics['loss']):8.3f}{ok:>8s}")
    print("\nall architectures exercised through the public API.")


if __name__ == "__main__":
    main()
