"""Quickstart: train a tiny llama-family model on the synthetic corpus, then
serve it with LaCache and watch the cache stay constant-size while decoding
far past the budget.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.data.pipeline import CorpusConfig, SyntheticCorpus, lm_batches
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.serving.engine import Engine
from repro.train import trainer


def main():
    cfg = ModelConfig(
        name="quickstart", arch_type="dense", n_layers=4, d_model=128,
        n_heads=8, n_kv_heads=4, head_dim=16, d_ff=384, vocab_size=512,
        dtype="float32",
        lacache=LaCacheConfig(budget=96, n_sink=4, n_recent=16, chunk=4))

    print("== 1. init + train 80 steps ==")
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=512))
    params, hist = trainer.train(
        cfg, params, lm_batches(corpus, 8, 128, 80),
        AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=80), log_every=20)

    print("\n== 2. serve with LaCache (budget 96 slots/layer) ==")
    eng = Engine(cfg, params, budget=96)
    prompt = np.stack([corpus.stream(300, seed=1)])  # 3x over budget
    out = eng.generate(prompt, 32)
    print("generated 32 tokens:", out[0].tolist())

    print("\n== 3. O(1) memory check ==")
    state = eng.new_state(1)
    print(f"cache bytes (independent of sequence length): "
          f"{eng.cache_bytes(state)/1e6:.2f} MB")
    nll = eng.score_stream(np.stack([corpus.stream(600, seed=2)]))
    print(f"streamed 600 tokens through a 96-slot cache; "
          f"mean NLL {nll.mean():.3f} (finite => continuous generation works)")


if __name__ == "__main__":
    main()
