"""The paper's Sec 3.3 claim, live: generate indefinitely through a
fixed-size cache, printing the cache occupancy as iterative compaction
fires (ladder pattern re-applied whenever a layer's budget fills).

  PYTHONPATH=src python examples/infinite_generation.py [--tokens 512]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_model, corpus, with_policy
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=512)
    ap.add_argument("--budget", type=int, default=96)
    args = ap.parse_args()

    cfg, params = bench_model()
    c = with_policy(cfg, "lacache", args.budget)
    eng = Engine(c, params, budget=args.budget)
    co = corpus()
    prompt = np.stack([co.stream(64, seed=5)])
    logits, state = eng.prefill(jnp.asarray(prompt))

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    lengths_trace = []
    for i in range(args.tokens):
        logits, state = eng._decode(eng.params, state=state, tokens=tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        if (i + 1) % 64 == 0:
            # per-layer occupied slots (post-compaction lengths differ by rung)
            lens = np.asarray(jax.tree.leaves(
                {k: v.length for k, v in state.blocks.items()})[0])
            lengths_trace.append((i + 1, int(state.pos), lens.tolist()))
            print(f"step {i+1:5d} abs-pos {int(state.pos):6d} "
                  f"per-layer cache lengths {lens.tolist()} "
                  f"(budget {args.budget})")
    final = lengths_trace[-1][2]
    assert max(final) <= args.budget
    print(f"\ndecoded {args.tokens} tokens; cache never exceeded "
          f"{args.budget} slots/layer. Memory is O(1) in output length.")


if __name__ == "__main__":
    main()
